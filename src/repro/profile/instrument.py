"""InstrumentedPlan / WorkloadReport: one forward pass -> Table-3/4 breakdown.

``plan.instrument(machine=A100)`` wraps a ``GraphExecutionPlan`` so that one
``run_model`` call records, per layer and per *executed* phase, what the
paper's Tables 3-5 tabulate: phase name, backend tier, ordering, analytic
FLOPs / bytes / arithmetic intensity, collective bytes (distributed plans),
and measured wall time -- into a typed ``WorkloadReport`` with ``to_json()``
and ``to_markdown()`` renderers.

The records come from a probe threaded through the SAME dispatch code the
plan replays in production (``core.plan._execute_layer``), not a parallel
re-implementation -- so ``WorkloadReport.mismatches(plan)`` is a real
regression guard: it cross-checks the decisions ``plan.describe()`` *claims*
against the phases that actually executed (ordering from the phase sequence,
backend from the aggregation record, fusion from whether the fused phase
ran).

Wall times follow the repo-wide convention (benchmarks/common.py): on CPU
they are correctness-shaped observables, not accelerator predictions; the
analytic FLOP/byte columns are machine-independent and exact.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax

from repro.profile.machine import Machine, machine_for_backend

_DTYPE_BYTES = 4  # the framework's f32 feature convention

#: every phase name a record may carry (schema-validated)
PHASES = ("aggregate", "combine", "fused_agg_combine", "distributed")

SCHEMA = "repro.profile/workload-report"
SCHEMA_VERSION = 1


class WorkloadReportError(ValueError):
    """A WorkloadReport violated its schema (empty/ill-typed records)."""


@dataclass(frozen=True)
class PhaseRecord:
    """One executed phase of one layer, with analytic costs + wall time.

    ``feature_len`` is the feature length the phase actually moved (for
    aggregation phases this is the paper's Table-4 variable: dout under
    combine-first, din under aggregate-first).  ``bound`` classifies the
    phase's arithmetic intensity against the report's Machine balance.
    """

    layer: int
    phase: str              # one of PHASES
    order: str
    backend: str
    fused: bool
    feature_len: int
    flops: float
    bytes: float
    collective_bytes: float
    wall_time_s: float
    bound: str              # "memory" | "compute" vs the report's Machine

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(1.0, self.bytes)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "layer": self.layer, "phase": self.phase, "order": self.order,
            "backend": self.backend, "fused": self.fused,
            "feature_len": self.feature_len, "flops": self.flops,
            "bytes": self.bytes,
            "arithmetic_intensity": self.arithmetic_intensity,
            "collective_bytes": self.collective_bytes,
            "wall_time_s": self.wall_time_s, "bound": self.bound,
        }


class _Probe:
    """Threaded through ``core.plan._execute_layer`` to observe dispatch.

    ``run(name, thunk, lp=..., **meta)`` executes the phase, blocks on its
    result for a wall time, derives the phase's analytic cost from the
    graph + layer plan, and appends a PhaseRecord.  Record order IS
    execution order (the ordering consistency check depends on that).
    """

    def __init__(self, plan, machine: Machine):
        self.plan = plan
        self.machine = machine
        self.records: List[PhaseRecord] = []

    def run(self, name: str, thunk, *, lp, **meta):
        from repro.core.backend import resolve_backend
        t0 = time.perf_counter()
        out = thunk()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        flops, byt, coll, flen = self._cost(name, lp, meta)
        ai = flops / max(1.0, byt)
        # backend as the dispatch layer resolves it at call time (the same
        # resolution phases.aggregate applies) -- NOT lp.backend verbatim,
        # so a plan that regressed to storing an unresolved alias ("auto" /
        # "pallas") is caught by mismatches() as describe-vs-dispatch drift
        self.records.append(PhaseRecord(
            layer=lp.index, phase=name, order=lp.order,
            backend=resolve_backend(lp.backend) if name != "combine"
            else "xla",
            fused=(name == "fused_agg_combine"),
            feature_len=int(flen), flops=float(flops), bytes=float(byt),
            collective_bytes=float(coll), wall_time_s=float(dt),
            bound=self.machine.classify(ai)))
        return out

    # -- analytic per-phase costs (same models the scheduler prices) --------

    def _cost(self, name, lp, meta):
        from repro.core.phases import aggregate_cost, combine_cost
        g = self.plan.g
        v = g.num_vertices
        if name == "aggregate":
            flen = meta["feature_len"]
            c = aggregate_cost(g, flen, include_self=lp.include_self)
            return c["flops"], c["bytes"], 0.0, flen
        if name == "combine":
            dims = meta["dims"]
            c = combine_cost(v, dims)
            return c["flops"], c["bytes"], 0.0, dims[-1]
        if name == "fused_agg_combine":
            # aggregate + first matmul in one tile: the (V, din) intermediate
            # never round-trips HBM, so its write+read bytes are subtracted.
            din, dout = meta["dims"]
            agg = aggregate_cost(g, din, include_self=lp.include_self)
            comb = combine_cost(v, (din, dout))
            saved = 2 * v * din * _DTYPE_BYTES
            byt = max(agg["bytes"] + comb["bytes"] - saved, 1)
            return agg["flops"] + comb["flops"], byt, 0.0, din
        if name == "distributed":
            # whole layer behind shard_map; collective term from the halo
            # model at the feature length the exchange actually moves.
            flen = meta["feature_len"]
            agg = aggregate_cost(g, flen, include_self=lp.include_self)
            comb = combine_cost(v, lp.dims)
            coll = self._halo_bytes(flen)
            return (agg["flops"] + comb["flops"],
                    agg["bytes"] + comb["bytes"], coll, flen)
        raise ValueError(f"unknown phase {name!r}")

    def _halo_bytes(self, feature_len: int) -> float:
        from repro.core.distributed import halo_bytes, halo_bytes_2d
        if self.plan.partition_kind == "2d":
            return float(halo_bytes_2d(self.plan.partition,
                                       feature_len)["min_halo_bytes"])
        if self.plan.partition_kind == "1d":
            return float(halo_bytes(self.plan.partition,
                                    feature_len)["min_halo_bytes"])
        return 0.0


# ---------------------------------------------------------------------------
# WorkloadReport
# ---------------------------------------------------------------------------


_FIELD_TYPES = {
    "layer": int, "phase": str, "order": str, "backend": str, "fused": bool,
    "feature_len": int, "flops": (int, float), "bytes": (int, float),
    "arithmetic_intensity": (int, float), "collective_bytes": (int, float),
    "wall_time_s": (int, float), "bound": str,
}


def validate_report_dict(d: Dict[str, Any]) -> List[str]:
    """Structural validation of a report in dict form; returns problems.

    Works on freshly rendered ``to_dict()`` output AND on deserialized
    ``to_json()`` artifacts -- the totals-vs-phases cross-check is only
    meaningful for the latter (a live report recomputes totals from its
    records, a JSON file can be edited or truncated independently).
    """
    problems: List[str] = []
    if d.get("schema") != SCHEMA or d.get("version") != SCHEMA_VERSION:
        problems.append("schema header mismatch")
    phases_list = d.get("phases", [])
    if not phases_list:
        problems.append("empty phase records")
    for i, rec in enumerate(phases_list):
        for k, t in _FIELD_TYPES.items():
            if k not in rec:
                problems.append(f"phases[{i}]: missing field {k!r}")
            elif not isinstance(rec[k], t) or isinstance(rec[k], bool) \
                    and t is not bool:
                problems.append(
                    f"phases[{i}].{k}: bad type {type(rec[k]).__name__}")
        if rec.get("phase") not in PHASES:
            problems.append(f"phases[{i}]: unknown phase "
                            f"{rec.get('phase')!r}")
        if rec.get("bound") not in ("memory", "compute"):
            problems.append(f"phases[{i}]: bad bound {rec.get('bound')!r}")
        for k in ("flops", "bytes", "collective_bytes", "wall_time_s"):
            if isinstance(rec.get(k), (int, float)) and rec[k] < 0:
                problems.append(f"phases[{i}].{k}: negative")
    tot = d.get("totals", {})
    for k in ("flops", "bytes", "collective_bytes"):
        if k not in tot:
            problems.append(f"totals.{k}: missing")
            continue
        s = sum(r[k] for r in phases_list
                if isinstance(r.get(k), (int, float)))
        if abs(s - tot[k]) > 1e-6 * max(1.0, abs(s)):
            problems.append(f"totals.{k} != sum of phases")
    return problems


@dataclass
class WorkloadReport:
    """Typed per-phase characterization of one instrumented forward pass.

    ``records`` are in execution order.  ``output`` carries the forward
    result (so ``plan.instrument(...).run_model(...)`` is one call that
    yields BOTH the model output and the report); it is excluded from
    ``to_dict``/``to_json``.
    """

    machine: Machine
    plan_summary: Dict[str, Any]
    records: List[PhaseRecord]
    output: Any = None

    # -- aggregation ---------------------------------------------------------

    def totals(self) -> Dict[str, float]:
        """Summed FLOPs / bytes / collective bytes / wall time over phases."""
        return {
            "flops": sum(r.flops for r in self.records),
            "bytes": sum(r.bytes for r in self.records),
            "collective_bytes": sum(r.collective_bytes
                                    for r in self.records),
            "wall_time_s": sum(r.wall_time_s for r in self.records),
        }

    def layer_records(self, layer: int) -> List[PhaseRecord]:
        return [r for r in self.records if r.layer == layer]

    # -- renderers -----------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        m = self.machine
        return {
            "schema": SCHEMA,
            "version": SCHEMA_VERSION,
            "machine": {"name": m.name, "kind": m.kind,
                        "peak_flops": m.peak_flops, "hbm_bw": m.hbm_bw,
                        "balance": m.balance},
            "plan": dict(self.plan_summary),
            "phases": [r.to_dict() for r in self.records],
            "totals": self.totals(),
        }

    def to_json(self, indent: int = 2) -> str:
        """Stable JSON rendering (sorted keys) of ``to_dict``."""
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def to_markdown(self) -> str:
        """Paper-style per-phase breakdown table (Tables 3/4 in one view)."""
        m = self.machine
        tot = self.totals()
        t_all = max(tot["wall_time_s"], 1e-12)
        lines = [
            f"## Workload report — {m.name}",
            "",
            f"Machine: {m.name} ({m.kind}): peak "
            f"{m.peak_flops / 1e12:.1f} TFLOP/s, HBM "
            f"{m.hbm_bw / 1e9:.0f} GB/s, balance {m.balance:.1f} FLOP/B",
            f"Plan: {self.plan_summary.get('num_layers', '?')} layer(s), "
            f"partition={self.plan_summary.get('partition', 'none')}, "
            f"interpret={self.plan_summary.get('interpret')}",
            "",
            "| layer | phase | order | backend | FLOPs | bytes | AI (F/B) "
            "| bound | collective B | time (us) | time % |",
            "|---|---|---|---|---|---|---|---|---|---|---|",
        ]
        for r in self.records:
            lines.append(
                f"| {r.layer} | {r.phase} | {r.order} | {r.backend} | "
                f"{r.flops:.3e} | {r.bytes:.3e} | "
                f"{r.arithmetic_intensity:.2f} | {r.bound} | "
                f"{r.collective_bytes:.3g} | {r.wall_time_s * 1e6:.1f} | "
                f"{100 * r.wall_time_s / t_all:.1f} |")
        lines.append(
            f"| total |  |  |  | {tot['flops']:.3e} | {tot['bytes']:.3e} | "
            f"{tot['flops'] / max(1.0, tot['bytes']):.2f} |  | "
            f"{tot['collective_bytes']:.3g} | "
            f"{tot['wall_time_s'] * 1e6:.1f} | 100.0 |")
        return "\n".join(lines)

    # -- validation ----------------------------------------------------------

    def validate(self) -> "WorkloadReport":
        """Raise ``WorkloadReportError`` on schema violations.

        Checked (``validate_report_dict``): non-empty phase records, every
        record field present with the right type, phase/bound vocabulary,
        non-negative costs, totals consistent with the records.  Returns
        self so call sites can chain
        (``plan.instrument().run_model(p, x).validate()``).
        """
        problems = validate_report_dict(self.to_dict())
        if problems:
            raise WorkloadReportError(
                "WorkloadReport schema violations: " + "; ".join(problems))
        return self

    def mismatches(self, plan) -> List[str]:
        """Cross-check ``plan.describe()`` against the dispatched phases.

        What is genuinely *observed* (not copied from the plan) and
        therefore guarded: the executed phase sequence (ordering -- the
        combine/aggregate records are appended in execution order),
        whether the fused path actually ran (``run_phases`` with an inline
        bias may legitimately fall back at call time -- that fallback is
        exactly the drift this reports; model-path plans must always come
        back clean), and the call-time backend *resolution* (a plan
        storing an unresolved "auto"/"pallas" alias disagrees with what
        dispatch resolves).  Kernel-entry tier selection below this layer
        is covered by tests/test_plan.py's mocked-platform tests, not
        here.  Empty list == describe() is truthful.
        """
        out: List[str] = []
        for d in plan.describe():
            recs = self.layer_records(d["layer"])
            if not recs:
                continue
            seq = [r.phase for r in recs]
            fused_ran = "fused_agg_combine" in seq
            if bool(d["fused"]) != fused_ran:
                out.append(f"layer {d['layer']}: describe fused={d['fused']} "
                           f"but executed phases {seq}")
            agg = [r for r in recs
                   if r.phase in ("aggregate", "fused_agg_combine",
                                  "distributed")]
            for r in agg:
                if r.backend != d["backend"]:
                    out.append(f"layer {d['layer']}: describe backend="
                               f"{d['backend']} but {r.phase} used "
                               f"{r.backend}")
            if not fused_ran and "aggregate" in seq and "combine" in seq:
                observed = ("combine_first"
                            if seq.index("combine") < seq.index("aggregate")
                            else "aggregate_first")
                if observed != d["order"]:
                    out.append(f"layer {d['layer']}: describe order="
                               f"{d['order']} but executed {seq}")
        return out


# ---------------------------------------------------------------------------
# InstrumentedPlan
# ---------------------------------------------------------------------------


class InstrumentedPlan:
    """A ``GraphExecutionPlan`` whose runs yield ``WorkloadReport``s.

    Built by ``plan.instrument(machine=...)``; ``machine`` defaults to the
    plan's own (``build_plan(..., machine=)``) or the first layer backend's
    natural preset.  Each ``run_*`` executes the plan's REAL dispatch path
    eagerly (per-phase wall times need phase boundaries, so no whole-model
    jit) and returns a fresh report whose ``.output`` is the forward result.
    """

    def __init__(self, plan, machine: Optional[Machine] = None,
                 warmup: int = 0):
        self.plan = plan
        self.machine = machine or getattr(plan, "machine", None) or \
            machine_for_backend(plan.layers[0].backend)
        self.warmup = warmup

    def _summary(self) -> Dict[str, Any]:
        p = self.plan
        return {
            "num_layers": p.num_layers,
            "partition": p.partition_kind,
            "interpret": p.interpret,
            "layers": p.describe(),
        }

    def _report(self, probe: _Probe, out) -> WorkloadReport:
        return WorkloadReport(machine=self.machine,
                              plan_summary=self._summary(),
                              records=probe.records, output=out)

    def run_model(self, params, x) -> WorkloadReport:
        """Instrumented full forward; returns the WorkloadReport (the model
        output rides along as ``report.output``)."""
        for _ in range(self.warmup):
            jax.block_until_ready(self.plan.run_model(params, x))
        probe = _Probe(self.plan, self.machine)
        out = self.plan.run_model(params, x, _probe=probe)
        return self._report(probe, out)

    def run_layer(self, params, x, *, layer: int = 0) -> WorkloadReport:
        """Instrumented single layer (conv param subtree)."""
        probe = _Probe(self.plan, self.machine)
        out = self.plan.run_layer(params, x, layer=layer, _probe=probe)
        return self._report(probe, out)

    def run_phases(self, x, weights, **kw) -> WorkloadReport:
        """Instrumented raw weight-list layer (``plan.run_phases``)."""
        probe = _Probe(self.plan, self.machine)
        out = self.plan.run_phases(x, weights, _probe=probe, **kw)
        return self._report(probe, out)
