"""Graph-convolution layers built on the phase primitives (paper Table 1).

  * GCNConv  -- mean({N(v)} ∪ {v}) ∘ Linear(|h|->d)      [combine-first legal]
  * SAGEConv -- same propagation rule as GCN (paper §2)   [combine-first legal]
  * GINConv  -- MLP(sum({N(v)} ∪ {v})), MLP = |h|->d->d   [aggregate-first only]

Parameters are plain pytrees (dicts) -- the framework is functional.
Each layer exposes ``apply(params, graph, x)`` plus ``init`` and
``resolve_order``.  Execution dispatches through a ``GraphExecutionPlan``
(core/plan.py): ordering, backend, and fusion are planned once per graph and
cached, not threaded through every call as raw ``impl=``/``blocked=`` flags.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.backend import AUTO
from repro.core.scheduler import (AGGREGATE_FIRST, COMBINE_FIRST,
                                  choose_ordering)
from repro.graph.structure import Graph


def _dense_init(key, din, dout, scale=None):
    scale = scale if scale is not None else (2.0 / din) ** 0.5
    return {"w": jax.random.normal(key, (din, dout), jnp.float32) * scale,
            "b": jnp.zeros((dout,), jnp.float32)}


class GCNConv:
    """Paper Eq. 1 with mean aggregation over {N(v)} ∪ {v}."""

    def __init__(self, din: int, dout: int, ordering: str = "auto",
                 backend: str = AUTO, fused: bool = False):
        self.din, self.dout = din, dout
        self.ordering = ordering
        self.backend = backend
        self.fused = fused

    def init(self, key) -> Dict:
        return {"lin": _dense_init(key, self.din, self.dout)}

    def resolve_order(self, g: Graph) -> str:
        if self.ordering in (COMBINE_FIRST, AGGREGATE_FIRST):
            return self.ordering
        return choose_ordering(g, self.din, self.dout, agg_op="mean",
                               n_mlp_layers=1, semantic_order=COMBINE_FIRST)

    def apply(self, params, g: Graph, x, *, plan=None):
        if plan is None:
            from repro.core.plan import plan_for_conv
            plan = plan_for_conv(self, g)
        return plan.run_layer(params, x)


class SAGEConv(GCNConv):
    """GraphSAGE-mean: identical per-layer rule (paper §2); differs upstream
    by mini-batch 2-hop sampling (graph/sampling.py)."""


class GINConv:
    """GIN-0 (paper Eq. 2): MLP(sum over {N(v)} ∪ {v}); MLP has an interior
    ReLU so the ordering is pinned to aggregate_first (scheduler enforces).
    With fusion enabled the plan fuses aggregation with the FIRST MLP matmul
    (exact: sum aggregation is linear, the ReLU applies after that matmul)."""

    def __init__(self, din: int, dout: int, hidden: Optional[int] = None,
                 backend: str = AUTO, fused: bool = False):
        self.din, self.dout = din, dout
        self.hidden = hidden or dout
        self.backend = backend
        self.fused = fused
        self.ordering = AGGREGATE_FIRST

    def init(self, key) -> Dict:
        k1, k2 = jax.random.split(key)
        return {"mlp1": _dense_init(k1, self.din, self.hidden),
                "mlp2": _dense_init(k2, self.hidden, self.dout)}

    def resolve_order(self, g: Graph) -> str:
        return AGGREGATE_FIRST

    def apply(self, params, g: Graph, x, *, plan=None):
        if plan is None:
            from repro.core.plan import plan_for_conv
            plan = plan_for_conv(self, g)
        return plan.run_layer(params, x)


CONVS = {"gcn": GCNConv, "sage": SAGEConv, "gin": GINConv}
