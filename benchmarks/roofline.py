"""Roofline table from dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per (arch x shape x mesh): the three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio, roofline fraction, and
fits-HBM.  This is a REPORTER -- it never touches jax devices, so it is a
graph-less ``BenchSpec`` whose measure only reads artifacts.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.profile.bench import BenchSpec, run_specs

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _report(ctx, _):
    if not DRYRUN_DIR.exists():
        ctx.emit("roofline/missing", 0.0,
                 note="run `python -m repro.launch.dryrun` first")
        return
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        try:
            recs.append(json.loads(p.read_text()))
        except Exception:  # noqa: BLE001
            continue
    for r in recs:
        if r.get("status") != "ok":
            ctx.emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                     tag=r.get("tag", "baseline"), status="ERROR",
                     error=r.get("error", "")[:80])
            continue
        rl = r["roofline"]
        ctx.emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                 rl["compute_s"] * 1e6,
                 tag=r.get("tag", "baseline"),
                 compute_s=f"{rl['compute_s']:.4f}",
                 memory_s=f"{rl['memory_s']:.4f}",
                 collective_s=f"{rl['collective_s']:.4f}",
                 dominant=rl["dominant"],
                 useful_ratio=round(rl["useful_ratio"], 3),
                 roofline_fraction=round(rl["roofline_fraction"], 4),
                 peak_gib=round(r.get("peak_bytes_per_device", 0) / 2 ** 30,
                                2),
                 fits_16g=r.get("fits_16g"))


SPECS = [BenchSpec(name="roofline", measure=_report, dry="run")]


def run():
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    run_specs(SPECS, csv=BENCH_ARTIFACT_DIR / "roofline.csv")


if __name__ == "__main__":
    run()
