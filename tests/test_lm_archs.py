"""Per-arch smoke tests: reduced config of the same family, one forward +
one train step on CPU, asserting output shapes and no NaNs (deliverable f).

The FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""

import dataclasses
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, get_config, list_archs
from repro.configs import ASSIGNED_ARCHS
from repro.launch.steps import make_train_step
from repro.models import encdec
from repro.models.transformer import init_lm, lm_forward, lm_loss
from repro.optim.optimizer import make_train_state

MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2",
    "arctic-480b": "arctic_480b",
    "deepseek-67b": "deepseek_67b",
    "gemma2-9b": "gemma2_9b",
    "gemma-7b": "gemma_7b",
    "granite-3-8b": "granite_3_8b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "internvl2-1b": "internvl2_1b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mamba2-2.7b": "mamba2_2_7b",
}


def reduced_cfg(arch):
    mod = importlib.import_module(f"repro.configs.{MODULES[arch]}")
    return dataclasses.replace(mod.reduced(), dtype="float32")


def test_all_archs_registered():
    assert set(ASSIGNED_ARCHS) <= set(list_archs())
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_cfg(arch)
    key = jax.random.PRNGKey(0)
    B, S = 2, 32
    opt = OptimizerConfig(warmup_steps=1, total_steps=10)

    if cfg.family == "audio":
        params = encdec.init_encdec(cfg, key)
        frames = jax.random.normal(key, (B, 16, cfg.d_model)) * 0.1
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"frames": frames, "tokens": toks, "labels": toks}
        memory = encdec.encode(params, cfg, frames)
        logits, _ = encdec.decode_stack(params, cfg, toks, memory)
        assert logits.shape == (B, S, cfg.padded_vocab)
    else:
        params = init_lm(cfg, key)
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        embeds = None
        batch = {"tokens": toks, "labels": toks}
        if cfg.frontend_stub:
            embeds = jax.random.normal(key, (B, 8, cfg.d_model)) * 0.1
            batch["embeds"] = embeds
        logits, aux = lm_forward(params, cfg, toks, embeds)
        n_pos = S + (8 if cfg.frontend_stub else 0)
        assert logits.shape == (B, n_pos, cfg.padded_vocab)
        assert not bool(jnp.isnan(logits).any()), "NaN in forward logits"

    state = make_train_state(params, opt)
    step = make_train_step(cfg, opt)
    new_state, metrics = step(state, batch)
    loss = float(np.asarray(metrics["loss"]))
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert int(new_state.step) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     state.params, new_state.params))
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_exact_assigned_numbers(arch):
    """The FULL config must carry the exact assignment-table numbers."""
    cfg = get_config(arch)
    expect = {
        "kimi-k2-1t-a32b": (61, 7168, 2048, 163840, 64, 8),
        "arctic-480b": (35, 7168, 4864, 32000, 56, 8),
        "deepseek-67b": (95, 8192, 22016, 102400, 64, 8),
        "gemma2-9b": (42, 3584, 14336, 256000, 16, 8),
        "gemma-7b": (28, 3072, 24576, 256000, 16, 16),
        "granite-3-8b": (40, 4096, 12800, 49155, 32, 8),
        "jamba-1.5-large-398b": (72, 8192, 24576, 65536, 64, 8),
        "internvl2-1b": (24, 896, 4864, 151655, 14, 2),
        "seamless-m4t-medium": (12, 1024, 4096, 256206, 16, 16),
        "mamba2-2.7b": (64, 2560, 0, 50280, None, None),
    }[arch]
    L, d, dff, vocab, heads, kv = expect
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.d_ff == dff
    assert cfg.vocab_size == vocab
    if heads is not None:
        assert cfg.attention.num_heads == heads
        assert cfg.attention.num_kv_heads == kv
    else:
        assert cfg.attention is None and cfg.ssm is not None
        assert cfg.ssm.d_state == 128


def test_moe_details():
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.moe.num_experts == 384 and kimi.moe.top_k == 8
    arctic = get_config("arctic-480b")
    assert arctic.moe.num_experts == 128 and arctic.moe.top_k == 2
    assert arctic.moe.dense_residual
    jamba = get_config("jamba-1.5-large-398b")
    assert jamba.moe.num_experts == 16 and jamba.attn_every == 8


def test_param_counts_match_published():
    expected = {
        "kimi-k2-1t-a32b": (1.04e12, 0.05), "arctic-480b": (480e9, 0.05),
        "deepseek-67b": (67e9, 0.05), "gemma2-9b": (9.2e9, 0.08),
        "gemma-7b": (8.5e9, 0.08), "granite-3-8b": (8.1e9, 0.08),
        "jamba-1.5-large-398b": (398e9, 0.05), "mamba2-2.7b": (2.7e9, 0.1),
    }
    for arch, (n, tol) in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < tol, f"{arch}: {got:.3e} vs {n:.3e}"
    assert abs(get_config("kimi-k2-1t-a32b").active_param_count() - 32e9) \
        < 3e9
    assert abs(get_config("jamba-1.5-large-398b").active_param_count()
               - 94e9) < 5e9


def test_shape_skips_documented():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if cfg.shape_skips:
            assert cfg.skip_reason, f"{arch} skips without a reason"
    # exactly the sub-quadratic-capable archs run long_500k
    runners = [a for a in ASSIGNED_ARCHS
               if "long_500k" not in get_config(a).shape_skips]
    assert sorted(runners) == ["gemma2-9b", "jamba-1.5-large-398b",
                               "mamba2-2.7b"]
