"""Decoder LM backbone for all assigned architectures.

Heterogeneous layer stacks (gemma2 local/global alternation, jamba 1:7
attn:mamba + alternating MoE) are handled by grouping layers into a repeating
PERIOD of positions; parameters are stacked per position over period
repetitions and the stack executes under one ``lax.scan`` -- HLO stays
period-sized regardless of depth (95-layer deepseek compiles the same program
as a 1-layer toy), which is what keeps the 512-device dry-runs tractable.

Entry points:
  init_lm(cfg, key)                         -> params pytree
  lm_forward(params, cfg, tokens, ...)      -> logits           (train/eval)
  lm_prefill(params, cfg, tokens, cache_sz) -> (logits, caches) (serving)
  lm_decode_step(params, cfg, tok, caches)  -> (logits, caches) (serving)
  lm_loss(params, cfg, tokens, labels)      -> scalar + metrics
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import LMConfig
from repro.launch.sharding import constrain
from repro.models.mamba2 import SSMCache, init_mamba2, mamba2_block
from repro.models.moe import init_moe, moe_ffn
from repro.nn.attention import (KVCache, attention_block, init_attention)
from repro.nn.layers import (embed, init_embedding, init_mlp, init_rmsnorm,
                             mlp, rmsnorm, softcap, unembed)


# ---------------------------------------------------------------------------
# Layer-period machinery
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerPos:
    """Static description of one position inside the repeating period."""
    index: int
    kind: str        # "attn" | "ssm"
    moe: bool
    local: bool      # sliding-window attention (gemma2 even layers)


def _lcm(a, b):
    return a * b // math.gcd(a, b)


def layer_period(cfg: LMConfig) -> int:
    p = 1
    if cfg.attention is not None and cfg.attention.local_global_alternate:
        p = _lcm(p, 2)
    if cfg.ssm is not None and cfg.attention is not None and cfg.attn_every:
        p = _lcm(p, cfg.attn_every)
    if cfg.moe is not None and cfg.moe.layer_pattern == "every_2":
        p = _lcm(p, 2)
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    return p


def layer_positions(cfg: LMConfig) -> List[LayerPos]:
    return [LayerPos(i,
                     "attn" if cfg.layer_is_attention(i) else "ssm",
                     cfg.layer_is_moe(i),
                     cfg.layer_is_local(i))
            for i in range(layer_period(cfg))]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_one_layer(key, cfg: LMConfig, pos: LayerPos, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": init_rmsnorm(cfg.d_model),
                         "ln2": init_rmsnorm(cfg.d_model)}
    if pos.kind == "attn":
        p["attn"] = init_attention(ks[0], cfg.d_model, cfg.attention, dtype)
    else:
        p["ssm"] = init_mamba2(ks[0], cfg.d_model, cfg.ssm, dtype)
    if pos.moe:
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe,
                            cfg.mlp_activation, dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                            cfg.mlp_activation, dtype)
    else:
        del p["ln2"]  # pure-SSM block (mamba2): no FFN sub-block
    if cfg.name.startswith("gemma2"):
        p["ln1_post"] = init_rmsnorm(cfg.d_model)
        p["ln2_post"] = init_rmsnorm(cfg.d_model)
    return p


def init_lm(cfg: LMConfig, key) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    positions = layer_positions(cfg)
    period = len(positions)
    n_rep = cfg.num_layers // period
    k_embed, k_head, k_layers = jax.random.split(key, 3)

    def init_rep(k):
        kk = jax.random.split(k, period)
        return {f"pos{p.index}": _init_one_layer(kk[p.index], cfg, p, dtype)
                for p in positions}

    layer_keys = jax.random.split(k_layers, n_rep)
    # vmap stacking: leaves become (n_rep, ...) arrays
    blocks = jax.vmap(init_rep)(layer_keys)

    params = {
        "embed": init_embedding(k_embed, cfg.padded_vocab, cfg.d_model,
                                dtype),
        "final_ln": init_rmsnorm(cfg.d_model),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(k_head, cfg.padded_vocab,
                                           cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_layer(lp: Dict, x, cfg: LMConfig, pos: LayerPos, *,
                 cache=None, make_cache=False, cache_size=0,
                 attn_impl="auto"):
    """One transformer/ssm block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    window = cfg.attention.sliding_window if (
        pos.kind == "attn" and pos.local) else 0
    sandwich = "ln1_post" in lp

    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if pos.kind == "attn":
        out, new_inner = attention_block(
            lp["attn"], h, cfg.attention, layer_window=window,
            cache=cache, make_cache=make_cache, cache_size=cache_size,
            impl=attn_impl)
        out = constrain(out, "batch", "seq", "embed")
    else:
        out, new_inner = mamba2_block(lp["ssm"], h, cfg.ssm, cache=cache,
                                      make_cache=make_cache)
    if sandwich:
        out = rmsnorm(lp["ln1_post"], out, cfg.norm_eps)
    x = x + out

    if "ln2" in lp:  # mamba2 pure-SSM blocks have no FFN sub-block
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if pos.moe:
            # decode is dropless: capacity drops would corrupt generation
            out, aux = moe_ffn(lp["moe"], h, cfg.moe, cfg.mlp_activation,
                               dropless=cache is not None)
        else:
            out = mlp(lp["mlp"], h, cfg.mlp_activation)
        if sandwich:
            out = rmsnorm(lp["ln2_post"], out, cfg.norm_eps)
        x = x + out
    x = constrain(x, "batch", "seq", "embed")
    return x, new_inner, aux


def _cache_tree_slice(caches, rep):
    if caches is None:
        return None
    return jax.tree.map(lambda a: a[rep], caches)


def _run_stack(params, cfg: LMConfig, x, *, caches=None, cache_length=None,
               make_cache=False, cache_size=0, remat: str = "none",
               attn_impl="auto"):
    """Scan the layer stack.  Returns (x, new_caches, total_aux)."""
    positions = layer_positions(cfg)

    def period_body(carry, xs):
        h, aux_acc = carry
        h = h.astype(jnp.dtype(cfg.dtype))  # keep the saved carry bf16
        block_params, cache_slice = xs
        new_cache_slice = {}
        for pos in positions:
            lp = block_params[f"pos{pos.index}"]
            inner = None
            if cache_slice is not None and f"pos{pos.index}" in cache_slice:
                raw = cache_slice[f"pos{pos.index}"]
                if pos.kind == "attn":
                    inner = KVCache(raw["k"], raw["v"], cache_length)
                else:
                    inner = SSMCache(raw["state"], raw["conv"], cache_length)
            h, new_inner, aux = _apply_layer(
                lp, h, cfg, pos, cache=inner, make_cache=make_cache,
                cache_size=cache_size, attn_impl=attn_impl)
            if new_inner is not None:
                if pos.kind == "attn":
                    new_cache_slice[f"pos{pos.index}"] = {
                        "k": new_inner.k, "v": new_inner.v}
                else:
                    new_cache_slice[f"pos{pos.index}"] = {
                        "state": new_inner.state, "conv": new_inner.conv}
            aux_acc = aux_acc + aux
        return (h, aux_acc), (new_cache_slice or None)

    body = period_body
    if remat == "full":
        body = jax.checkpoint(period_body, prevent_cse=False)
    elif remat == "selective":
        body = jax.checkpoint(
            period_body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = (params["blocks"], caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        xs)
    return x, new_caches, aux


def _embed_inputs(params, cfg: LMConfig, tokens, embeds):
    scale = cfg.name.startswith(("gemma", "internvl")) is False
    x = embed(params["embed"], tokens,
              scale_by_sqrt_d=cfg.name.startswith("gemma"))
    if embeds is not None:  # VLM/audio frontend stub: prepend embeddings
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def _logits(params, cfg: LMConfig, x):
    x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(head, x)
    logits = softcap(logits, cfg.final_logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:  # mask padding ids to -inf
        pad_mask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                             0.0, -1e30).astype(logits.dtype)
        logits = logits + pad_mask
    return constrain(logits, "batch", "seq", "vocab")


def lm_forward(params, cfg: LMConfig, tokens, embeds=None,
               remat: str = "none", attn_impl: str = "auto") -> jnp.ndarray:
    """Full-sequence forward -> logits (B, S_total, vocab)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    x = constrain(x, "batch", "seq", "embed")
    x, _, aux = _run_stack(params, cfg, x, remat=remat, attn_impl=attn_impl)
    return _logits(params, cfg, x), aux


def lm_loss(params, cfg: LMConfig, tokens, labels, embeds=None,
            remat: str = "none", attn_impl: str = "auto",
            ce_chunk: int = 2048):
    """Next-token CE, computed CHUNKED over tokens.

    Materializing full (tokens, vocab) f32 logits dominates peak memory at
    256k-vocab scale (observed: 4x 2.5 GiB/device buffers at kimi-k2).  The
    unembed + log-softmax therefore run per token-chunk under jax.checkpoint
    -- the classic chunked-CE trick; backward recomputes chunk logits.

    labels == -100 are masked (frontend positions, padding).
    """
    x = _embed_inputs(params, cfg, tokens, embeds)
    x = constrain(x, "batch", "seq", "embed")
    x, _, aux = _run_stack(params, cfg, x, remat=remat, attn_impl=attn_impl)
    x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
    if embeds is not None:  # frontend prefix positions carry no labels
        x = x[:, embeds.shape[1]:]
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    table = head["table"]

    b, s, d = x.shape
    t = b * s
    chunk = min(ce_chunk, t)
    if t % chunk != 0:
        chunk = t  # fallback: unchunked for odd tiny shapes
    xf = x.reshape(t, d)
    lf = labels.reshape(t)

    @jax.checkpoint
    def chunk_ce(x_c, l_c):
        logits = jnp.einsum("td,vd->tv", x_c, table.astype(x_c.dtype),
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, cfg.final_logit_softcap)
        if cfg.padded_vocab != cfg.vocab_size:
            pad = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                            0.0, -1e30).astype(logits.dtype)
            logits = logits + pad
        valid = l_c >= 0
        safe = jnp.where(valid, l_c, 0)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, safe[:, None], axis=-1)[:, 0]
        return (nll * valid).sum(), valid.sum()

    def body(carry, io):
        x_c, l_c = io
        tot, cnt = carry
        ls, n = chunk_ce(x_c, l_c)
        return (tot + ls, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xf.reshape(t // chunk, chunk, d), lf.reshape(t // chunk, chunk)))
    loss = tot / jnp.maximum(cnt, 1)
    return loss + aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_caches_abstract(cfg: LMConfig, batch: int, cache_size: int):
    """ShapeDtypeStructs for the stacked cache pytree (decode dry-runs)."""
    positions = layer_positions(cfg)
    n_rep = cfg.num_layers // len(positions)
    dtype = jnp.dtype(cfg.dtype)
    tree = {}
    for pos in positions:
        if pos.kind == "attn":
            a = cfg.attention
            shp = (n_rep, batch, a.num_kv_heads, cache_size, a.head_dim)
            tree[f"pos{pos.index}"] = {
                "k": jax.ShapeDtypeStruct(shp, dtype),
                "v": jax.ShapeDtypeStruct(shp, dtype)}
        else:
            s = cfg.ssm
            h = s.n_heads(cfg.d_model)
            conv_dim = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
            tree[f"pos{pos.index}"] = {
                "state": jax.ShapeDtypeStruct(
                    (n_rep, batch, h, s.d_state, s.head_dim), jnp.float32),
                "conv": jax.ShapeDtypeStruct(
                    (n_rep, batch, conv_dim, s.d_conv - 1), jnp.float32)}
    return tree


def lm_prefill(params, cfg: LMConfig, tokens, cache_size: int, embeds=None,
               attn_impl: str = "auto"):
    """Forward + cache build.  Returns (last-token logits, caches, length)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    x = constrain(x, "batch", "seq", "embed")
    x, caches, _ = _run_stack(params, cfg, x, make_cache=True,
                              cache_size=cache_size, attn_impl=attn_impl)
    logits = _logits(params, cfg, x[:, -1:])
    length = jnp.asarray(x.shape[1], jnp.int32)
    return logits, caches, length


def lm_decode_step(params, cfg: LMConfig, token, caches, length,
                   attn_impl: str = "auto"):
    """One-token decode.  token: (B, 1) -> (logits, new_caches, new_length)."""
    x = embed(params["embed"], token,
              scale_by_sqrt_d=cfg.name.startswith("gemma"))
    x = constrain(x, "batch", "seq", "embed")
    x, new_caches, _ = _run_stack(params, cfg, x, caches=caches,
                                  cache_length=length, attn_impl=attn_impl)
    logits = _logits(params, cfg, x)
    return logits, new_caches, length + 1
