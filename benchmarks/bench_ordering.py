"""Paper Table 4: impact of phase ordering on the Aggregation phase.

Com->Agg vs Agg->Com on (scaled) Reddit with the paper's 602->128 layer:
  * analytic data accesses + computations (exact paper accounting, at both
    the scaled size AND the paper's full Reddit size),
  * measured wall-clock of both orderings (CPU; ratio is the observable),
  * the distributed restatement: halo bytes per ordering (DESIGN.md §8.5).

Paper reference values: 4.75x accesses, 4.72x ops, 4.76x time.
"""

from __future__ import annotations

import jax

from repro.config import GRAPHS
from repro.core.distributed import halo_bytes
from repro.core.phases import phase_ordered_layer
from repro.core.plan import plan_for_phases
from repro.core.scheduler import reduction_ratios
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.graph.partition import partition_1d
from repro.profile.bench import BenchSpec, run_specs

IN_LEN, OUT_LEN = 602, 128


def _analytic_full(ctx, _):
    """Full-size analytic table (the actual Table 4 reproduction)."""
    full = GRAPHS["reddit"]
    gfull = make_synthetic_graph(
        type(full)(full.name, full.num_vertices, full.feature_len,
                   full.num_edges, full.num_classes))
    r = reduction_ratios(gfull, IN_LEN, OUT_LEN)
    cf, af = r["combine_first"], r["aggregate_first"]
    ctx.emit("table4/full_reddit/analytic", 0.0,
             agg_bytes_com_first=cf.agg_bytes,
             agg_bytes_agg_first=af.agg_bytes,
             agg_flops_com_first=cf.agg_flops,
             agg_flops_agg_first=af.agg_flops,
             data_access_reduction=round(r["data_access_reduction"], 2),
             computation_reduction=round(r["computation_reduction"], 2),
             paper_reference="4.75x/4.72x")


def _measured_scaled(ctx, _):
    """Scaled measured table: both orderings as single-layer plans."""
    g, spec = ctx.g, ctx.spec
    x = make_features(type(spec)(spec.name, spec.num_vertices, IN_LEN,
                                 spec.num_edges, spec.num_classes))
    w = jax.random.normal(jax.random.PRNGKey(0),
                          (IN_LEN, OUT_LEN)) * 0.05
    plans = {order: plan_for_phases(g, [(w, None)], order=order,
                                    agg_op="mean")
             for order in ("combine_first", "aggregate_first")}
    cf_fn = jax.jit(lambda xx: phase_ordered_layer(
        g, xx, [(w, None)], agg_op="mean", activation="none",
        plan=plans["combine_first"]))
    af_fn = jax.jit(lambda xx: phase_ordered_layer(
        g, xx, [(w, None)], agg_op="mean", activation="none",
        plan=plans["aggregate_first"]))
    t_cf = ctx.time(cf_fn, x)
    t_af = ctx.time(af_fn, x)
    rs = reduction_ratios(g, IN_LEN, OUT_LEN)
    ctx.emit("table4/scaled_reddit/measured", t_cf,
             time_com_first_us=round(t_cf, 1),
             time_agg_first_us=round(t_af, 1),
             time_reduction=round(t_af / max(t_cf, 1e-9), 2),
             analytic_access_reduction=round(rs["data_access_reduction"], 2),
             planner_pick=plan_for_phases(
                 g, [(w, None)], order=None, agg_op="mean").layers[0].order)


def _distributed_halo(ctx, _):
    """Distributed restatement: halo bytes per ordering."""
    pg = partition_1d(ctx.g, 16, edge_balanced=False)
    hb_in = halo_bytes(pg, IN_LEN)["min_halo_bytes"]
    hb_out = halo_bytes(pg, OUT_LEN)["min_halo_bytes"]
    ctx.emit("table4/distributed_halo", 0.0,
             halo_bytes_agg_first=hb_in, halo_bytes_com_first=hb_out,
             collective_reduction=round(hb_in / hb_out, 2))


SPECS = [
    BenchSpec(name="table4/analytic", measure=_analytic_full),
    BenchSpec(name="table4/measured", graph="reddit", max_vertices=8192,
              measure=_measured_scaled),
    BenchSpec(name="table4/halo", graph="reddit", max_vertices=8192,
              measure=_distributed_halo),
]


def run():
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    run_specs(SPECS, csv=BENCH_ARTIFACT_DIR / "bench_ordering.csv")


if __name__ == "__main__":
    run()
