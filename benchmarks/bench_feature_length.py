"""Paper Fig. 5: execution time vs input/output feature length (SAG, Reddit).

(a) sweep input length at fixed out=128: Combination time ~ linear in
    in_len, Aggregation time CONSTANT (combine-first: independent of in_len);
(b) sweep output length at fixed in=602: both phases ~ linear in out_len.

Sweet spots: the paper sees power-of-2 dips on V100; the machine analogue is
matrix-tile alignment (``machine.matrix_tile``: 128-lane MXU on TPU),
reported as pad waste (ceil to the tile).  Both sweeps are one BenchSpec
each -- the sweep axis IS the feature length.
"""

from __future__ import annotations

import jax

from repro.core.phases import aggregate, aggregate_cost, combine_cost
from repro.profile.bench import BenchSpec, run_specs

IN_LENS = (64, 128, 250, 256, 512, 602, 1024)
OUT_LENS = (16, 64, 100, 128, 256, 512)


def _pad_waste(length: int, tile: int) -> float:
    return round(tile * -(-length // tile) / length - 1, 3)


def _sweep_in(ctx, in_len):
    """(a) input length sweep, out fixed at 128 (combine first)."""
    g = ctx.g
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (g.num_vertices, in_len))
    w = jax.random.normal(key, (in_len, 128)) * 0.05
    t_comb = ctx.time(jax.jit(lambda xx: xx @ w), x)
    t_agg = ctx.time(jax.jit(lambda hh: aggregate(g, hh, op="mean")), x @ w)
    ctx.emit(f"fig5a/in_{in_len}", t_comb + t_agg,
             comb_us=round(t_comb, 1), agg_us=round(t_agg, 1),
             agg_analytic_bytes=aggregate_cost(g, 128)["bytes"],
             mxu_pad_waste=_pad_waste(in_len, ctx.machine.matrix_tile))


def _sweep_out(ctx, out_len):
    """(b) output length sweep, in fixed at 602."""
    g = ctx.g
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (g.num_vertices, 602))
    w = jax.random.normal(key, (602, out_len)) * 0.05
    t_comb = ctx.time(jax.jit(lambda xx: xx @ w), x)
    t_agg = ctx.time(jax.jit(lambda hh: aggregate(g, hh, op="mean")), x @ w)
    ctx.emit(f"fig5b/out_{out_len}", t_comb + t_agg,
             comb_us=round(t_comb, 1), agg_us=round(t_agg, 1),
             agg_analytic_bytes=aggregate_cost(g, out_len)["bytes"],
             comb_analytic_flops=combine_cost(g.num_vertices,
                                              (602, out_len))["flops"],
             mxu_pad_waste=_pad_waste(out_len, ctx.machine.matrix_tile))


SPECS = [
    BenchSpec(name="fig5a", graph="reddit", max_vertices=4096,
              sweep=IN_LENS, measure=_sweep_in),
    BenchSpec(name="fig5b", graph="reddit", max_vertices=4096,
              sweep=OUT_LENS, measure=_sweep_out),
]


def run():
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    run_specs(SPECS, csv=BENCH_ARTIFACT_DIR / "bench_feature_length.csv")


if __name__ == "__main__":
    run()
