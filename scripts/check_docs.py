#!/usr/bin/env python
"""Docs gate (scripts/smoke.sh step 3).

Fails (exit 1, listing every violation) unless:

  * README.md and docs/planner.md exist and are non-trivial,
  * every public planner-surface symbol has a real docstring,
  * the planner entry points' docstrings carry worked examples / the
    documented mesh contract (the pieces ISSUE reviews keep asking for).

Run from anywhere: ``python scripts/check_docs.py``.
"""

from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

#: module path -> public symbols whose docstrings are part of the contract
PUBLIC_SURFACE = {
    "repro.core.plan": [
        "build_plan", "plan_for_conv", "plan_for_phases",
        "GraphExecutionPlan", "GraphExecutionPlan.run_model",
        "GraphExecutionPlan.run_layer", "GraphExecutionPlan.run_phases",
        "GraphExecutionPlan.describe", "GraphExecutionPlan.layer_costs",
        "GraphExecutionPlan.instrument", "GraphExecutionPlan.compile",
        "CompiledPlan", "plan_cache_stats", "clear_plan_cache",
    ],
    "repro.serve.core": [
        "SlotServeCore", "SlotServeCore.submit", "SlotServeCore.run",
        "SlotServeCore.stats",
    ],
    "repro.serve.graph_engine": [
        "GraphServeEngine", "GraphServeEngine.warmup",
        "GraphServeEngine.prepare", "GraphServeEngine.run_prepared",
        "GraphServeEngine.run_eager", "GraphServeEngine.select_bucket",
        "GraphServeEngine.workload_report", "GraphServeEngine.stats",
        "GraphRequest", "Bucket", "Bucket.fits", "default_buckets",
        "union_two_hop",
    ],
    "repro.graph.reorder": [
        "degree_reorder", "choose_reorder", "reuse_distance_stats",
    ],
    "repro.graph.dedup": [
        "DedupLayout", "DedupLayout.flops_saved", "build_dedup_layout",
        "dedup_layout_for_graph", "dedup_cost", "pad_dedup_arrays",
        "attach_blocked",
    ],
    "repro.models.sage_minibatch": [
        "PlannedSageTrainer", "PlannedSageTrainer.train",
        "PlannedSageTrainer.step", "PlannedSageTrainer.save",
        "PlannedSageTrainer.restore", "PlannedSageTrainer.predict",
        "train_minibatch_planned",
    ],
    "repro.kernels.ops": ["seg_agg", "seg_agg_planned"],
    "repro.core.backend": [
        "resolve_backend", "interpret_for", "default_interpret",
        "pallas_tier",
    ],
    "repro.core.distributed": [
        "distributed_gcn_layer", "distributed_gcn_layer_2d",
        "pad_features_2d", "halo_bytes", "halo_bytes_2d",
        "overlap_model", "choose_overlap", "schedule_wire_bytes",
        "wire_dtype_bytes",
    ],
    "repro.graph.partition": [
        "partition_1d", "partition_2d", "Partition2D", "PartitionedGraph",
    ],
    "repro.core.dataflow": ["suggest_tile_m", "fused_gcn_layer"],
    "repro.core.phases": ["aggregate", "combine", "phase_ordered_layer"],
    "repro.profile.machine": [
        "Machine", "Machine.tile_budget", "Machine.classify",
        "Machine.hop_time", "Machine.matmul_peak", "get_machine",
        "machine_for_backend", "choose_dtype", "dtype_model",
        "choose_dedup", "dedup_model",
    ],
    "repro.profile.instrument": [
        "InstrumentedPlan", "InstrumentedPlan.run_model", "WorkloadReport",
        "WorkloadReport.to_json", "WorkloadReport.to_markdown",
        "WorkloadReport.validate", "WorkloadReport.mismatches",
        "PhaseRecord",
    ],
    "repro.profile.bench": [
        "BenchSpec", "BenchContext", "run_specs", "timeit", "write_csv",
        "bench_graph",
    ],
    "repro.analysis.report": [
        "Finding", "AnalysisReport", "AnalysisReport.add",
        "AnalysisReport.ok", "AnalysisReport.to_json",
        "AnalysisReport.to_markdown", "AnalysisReport.counts",
    ],
    "repro.analysis.jaxpr_lint": [
        "lint_plan", "lint_callable", "collective_bytes",
        "plan_expected_collectives", "check_donation", "iter_eqns",
    ],
    "repro.analysis.ast_lint": [
        "lint_tree", "lint_file", "lint_source",
    ],
    "repro.analysis.selftest": ["run_selftest", "check_suppression"],
}

#: docstring must contain these substrings (entry point -> requirements)
CONTENT_REQUIREMENTS = {
    ("repro.core.plan", "build_plan"): [">>>", "mesh", "num_shards",
                                        "reorder", "degree", "auto",
                                        "overlap", "pipelined", "dtype",
                                        "bf16", "dedup", "pairs",
                                        "dedup_pad"],
    ("repro.profile.machine", "choose_dtype"): [
        ">>>", "bf16", "native_bf16", "halo"],
    ("repro.profile.machine", "choose_dedup"): [
        ">>>", "pairs", "fanout", "Machine"],
    ("repro.core.distributed", "choose_overlap"): [
        "pipelined", "hop", "Machine", ">>>"],
    ("repro.core.distributed", "overlap_model"): [
        "exposed", "overlapped", "hop_time"],
    ("repro.core.plan", "plan_for_conv"): [">>>"],
    ("repro.core.plan", "plan_for_phases"): [">>>"],
    ("repro.core.backend", "resolve_backend"): ["auto", "pallas-gpu",
                                                "pallas-tpu"],
    ("repro.core.plan", "GraphExecutionPlan.instrument"): [
        ">>>", "WorkloadReport", "machine"],
    ("repro.core.plan", "GraphExecutionPlan.compile"): [
        ">>>", "donate", "retrace", "layer", "dynamic"],
    ("repro.kernels.ops", "seg_agg"): ["seg_agg_planned", "host"],
    ("repro.analysis.jaxpr_lint", "lint_plan"): [
        "eager", "compiled", "donate", "dynamic", "never execute"],
    ("repro.analysis.ast_lint", "lint_source"): ["pragma", "allow"],
    ("repro.core.distributed", "schedule_wire_bytes"): [
        "Schedule-exact", "ring", "overlap", "reduce_scatter",
        "wire_dtype_bytes"],
    ("repro.serve.graph_engine", "GraphServeEngine.warmup"): [
        "compile", "admission", "clear_plan_cache"],
}

REQUIRED_FILES = {
    ROOT / "README.md": ["Quickstart", "smoke.sh",
                         "test_ctx_parallel_attention_sharded"],
    ROOT / "docs" / "planner.md": ["decision table", "pallas-gpu",
                                   "partition_2d", "characterization.md",
                                   "plan.compile", "reorder",
                                   "degree_reorder",
                                   "Overlapped halo execution",
                                   "choose_overlap", "pipelined",
                                   "double-buffered", "bench_overlap",
                                   "Reduced-precision execution",
                                   "choose_dtype", "int8-agg",
                                   "bench_dtype", "quant_error",
                                   "Redundancy-eliminated aggregation",
                                   "choose_dedup", "dedup_model",
                                   "DedupLayout", "two-level",
                                   "bench_dedup", "dedup_pairs"],
    ROOT / "docs" / "characterization.md": [
        "Machine", "TPU_V5E", "TPU_V5P", "A100", "H100", "V100",
        "WorkloadReport", "to_markdown", "BenchSpec", "instrument",
        "workload-report", "balance", "compiled", "hop_time",
        "link_latency_s", "exposed_collective_time",
        "overlapped_collective_time", "dtype", "dtype_model",
        "matmul_peak"],
    ROOT / "docs" / "serving.md": [
        "GraphServeEngine", "SlotServeCore", "bucket", "warmup",
        "clear_plan_cache", "plan_cache_stats", "dynamic", "retrace",
        "p50", "p99", "throughput", "bench_serve", "two_hop_batch",
        "bit-identical", "eviction"],
    ROOT / "docs" / "training.md": [
        "PlannedSageTrainer", "GraphPipeline", "Checkpointer",
        "dedup", "choose_dedup", "dedup_pad", "bucket", "retrace",
        "plan_cache_stats", "batch_at", "deterministic", "resume",
        "bitwise", "tolerance"],
    ROOT / "docs" / "analysis.md": [
        "no-callbacks", "no-f64", "bf16-f32-accum", "donation",
        "collective-bytes", "dynamic-edge-free", "dedup-accounting",
        "host-in-trace",
        "tracer-branch", "broadcast-div", "acc-dtype", "grid-arity",
        "allow(", "allow-file(", "--strict", "--selftest",
        "wire_collective_bytes", "schedule_wire_bytes",
        "SEG_AGG_REMEDIATION", "tf.aliasing_output", "planner.md"],
}

MIN_DOC_LEN = 40  # a one-word docstring is not documentation


def _resolve(mod, dotted: str):
    obj = mod
    for part in dotted.split("."):
        obj = getattr(obj, part)
    return obj


def main() -> int:
    import importlib

    problems = []
    for path, needles in REQUIRED_FILES.items():
        if not path.is_file():
            problems.append(f"missing file: {path.relative_to(ROOT)}")
            continue
        text = path.read_text()
        if len(text) < 500:
            problems.append(f"{path.relative_to(ROOT)}: suspiciously short")
        for needle in needles:
            if needle not in text:
                problems.append(
                    f"{path.relative_to(ROOT)}: must mention {needle!r}")

    for mod_name, symbols in PUBLIC_SURFACE.items():
        try:
            mod = importlib.import_module(mod_name)
        except Exception as e:  # noqa: BLE001
            problems.append(f"cannot import {mod_name}: {e}")
            continue
        if not (mod.__doc__ and len(mod.__doc__) >= MIN_DOC_LEN):
            problems.append(f"{mod_name}: missing module docstring")
        for name in symbols:
            try:
                obj = _resolve(mod, name)
            except AttributeError:
                problems.append(f"{mod_name}.{name}: symbol missing")
                continue
            doc = getattr(obj, "__doc__", None)
            if not (doc and len(doc.strip()) >= MIN_DOC_LEN):
                problems.append(f"{mod_name}.{name}: missing/trivial "
                                "docstring")

    for (mod_name, sym), needles in CONTENT_REQUIREMENTS.items():
        try:
            doc = _resolve(importlib.import_module(mod_name), sym).__doc__ \
                or ""
        except Exception:  # noqa: BLE001
            continue  # already reported above
        for needle in needles:
            if needle not in doc:
                problems.append(
                    f"{mod_name}.{sym}: docstring must contain {needle!r}")

    if problems:
        print("check_docs: FAILED")
        for p in problems:
            print(f"  - {p}")
        return 1
    n = sum(len(v) for v in PUBLIC_SURFACE.values())
    print(f"check_docs: OK ({len(REQUIRED_FILES)} docs, {n} public symbols)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
