"""Planner sweep: ONE harness comparing backend x ordering x fusion x
partition.

Every scenario is expressed as a ``build_plan`` override, so this module
exercises exactly the dispatch layer production code uses -- no hand-built
kernel calls.  Emits one row per scenario with the plan's decisions
(order/RESOLVED backend/tile_m/interpret) plus measured wall-clock, and one
row per model with the decisions the planner takes when left on "auto".

``run(dry=True)`` (the ``benchmarks/run.py --dry-run`` path) builds and
validates every plan, emits the decisions without timing, and *accounts for
every scenario in the matrix*: anything skipped is reported with a reason,
and a scenario missing without one raises (scripts/smoke.sh fails).  The
partition scenarios (1-D and 2-D meshes) run in a subprocess with 8 fake
host devices so the main process keeps its single real device (the same
rule tests/test_distributed.py follows).

A backend is only *natively* exercised on its own platform; everywhere else
the Pallas tiers run in interpret mode.  The dry run prints exactly which
tiers were compiled vs interpreted so a GPU-less container can no longer
silently validate nothing but XLA paths.
"""

from __future__ import annotations

import itertools
import os
import subprocess
import sys
from pathlib import Path

import jax

from benchmarks.common import bench_graph, emit, timeit
from repro.core.backend import interpret_for, platform
from repro.core.plan import build_plan
from repro.core.scheduler import AGGREGATE_FIRST, COMBINE_FIRST
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.models.gcn import PAPER_MODELS, make_paper_model

BACKENDS = ("xla", "pallas-tpu", "pallas-gpu")
ORDERINGS = (None, COMBINE_FIRST, AGGREGATE_FIRST)  # None = cost model
FUSION = (False, True)

#: (kind, mesh shape, mesh axis names, halo strategy) -- subprocess matrix
PARTITIONS = (
    ("1d", (8,), ("data",), "ring"),
    ("1d", (8,), ("data",), "allgather"),
    ("2d", (4, 2), ("node", "feat"), "ring"),
    ("2d", (4, 2), ("node", "feat"), "allgather"),
    ("2d", (2, 4), ("node", "feat"), "ring"),
)


def _scenario_name(backend, ordering, fused):
    return (f"plan/gcn/{backend}/{ordering or 'auto'}/"
            f"{'fused' if fused else 'unfused'}")


def _partition_name(kind, shape, strategy):
    return f"plan/gcn/partition-{kind}/{'x'.join(map(str, shape))}/{strategy}"


def expected_matrix():
    """Every scenario name the dry run must account for."""
    names = [_scenario_name(b, o, f) for b, o, f in
             itertools.product(BACKENDS, ORDERINGS, FUSION)]
    names += [_partition_name(k, s, st) for k, s, _, st in PARTITIONS]
    return names


def _run_local_scenarios(spec, g, x, m, params, dry):
    validated = []
    for backend, ordering, fused in itertools.product(BACKENDS, ORDERINGS,
                                                      FUSION):
        plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                          backend=backend, ordering=ordering, fused=fused)
        d0 = plan.describe()[0]
        derived = dict(order=d0["order"], backend=d0["backend"],
                       fused=d0["fused"], tile_m=d0["tile_m"],
                       interpret=d0["interpret"], agg_bytes=d0["agg_bytes"])
        name = _scenario_name(backend, ordering, fused)
        if dry or backend != "xla":
            # interpret-mode wall-clock is meaningless; validate + describe
            out = plan.run_model(params, x) if dry else None
            if out is not None:
                assert out.shape == (spec.num_vertices, spec.num_classes)
            emit(name, 0.0, **derived)
        else:
            fn = jax.jit(lambda xx, p=plan: p.run_model(params, xx))
            emit(name, timeit(fn, x), **derived)
        validated.append(name)
    return validated


_PARTITION_CHILD_FLAG = "--partition-child"


def _partition_child():
    """Subprocess body: validate every partition scenario on fake devices."""
    import numpy as np
    spec = bench_graph("reddit", max_vertices=256, max_feature=64)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    m = make_paper_model("gcn", spec)
    params = m.init(jax.random.PRNGKey(0))
    ref = build_plan(g, m.cfg, spec.feature_len,
                     spec.num_classes).run_model(params, x)
    for kind, shape, names, strategy in PARTITIONS:
        mesh = jax.make_mesh(shape, names)
        plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                          mesh=mesh, strategy=strategy)
        assert plan.partition_kind == kind, (plan.partition_kind, kind)
        with mesh:
            out = plan.run_model(params, x)
        err = float(np.abs(np.asarray(out - ref)).max())
        assert err < 1e-3, (kind, shape, strategy, err)
        d0 = plan.describe()[0]
        emit(_partition_name(kind, shape, strategy), 0.0,
             order=d0["order"], backend=d0["backend"],
             partition=d0["partition"], max_err=f"{err:.2e}")
    print("PARTITION-CHILD-OK")


def _dry_run_partitions():
    """Spawn the partition matrix in a subprocess with 8 fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(Path(__file__).resolve().parents[1] / "src"),
         str(Path(__file__).resolve().parents[1])])
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_plan",
         _PARTITION_CHILD_FLAG],
        capture_output=True, text=True, env=env, timeout=600)
    sys.stdout.write(res.stdout)
    if res.returncode != 0 or "PARTITION-CHILD-OK" not in res.stdout:
        raise RuntimeError(
            f"partition dry-run subprocess failed:\n{res.stderr[-3000:]}")
    return [_partition_name(k, s, st) for k, s, _, st in PARTITIONS]


def run(dry: bool = False):
    spec = bench_graph("reddit", max_vertices=256 if dry else 2048,
                       max_feature=128)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    m = make_paper_model("gcn", spec)
    params = m.init(jax.random.PRNGKey(0))

    validated = _run_local_scenarios(spec, g, x, m, params, dry)
    skipped = {}
    if dry:
        validated += _dry_run_partitions()
    else:
        for name in (_partition_name(k, s, st) for k, s, _, st in PARTITIONS):
            skipped[name] = "partition timing needs a real multi-device mesh"

    # what does the planner decide unaided, per paper model?
    for name in ("gcn", "sage", "gin"):
        mm = make_paper_model(name, spec)
        plan = build_plan(g, mm.cfg, spec.feature_len, spec.num_classes)
        for d in plan.describe():
            emit(f"plan/auto/{name}/layer{d['layer']}", 0.0,
                 order=d["order"], backend=d["backend"], fused=d["fused"],
                 din=d["din"], dout=d["dout"], agg_bytes=d["agg_bytes"])

    # coverage report: which tiers ran compiled vs interpret-only, and
    # whether every matrix scenario is accounted for (fail loudly if not)
    plat = platform()
    compiled = [b for b in BACKENDS
                if b == "xla" or not interpret_for(b)]
    interp = [b for b in BACKENDS if b not in compiled]
    print(f"# backend coverage on platform={plat}: compiled natively: "
          f"{','.join(compiled)}; interpret-mode only (numerics validated, "
          f"perf NOT exercised): {','.join(interp) or 'none'}")
    for name, why in skipped.items():
        print(f"# skipped: {name} ({why})")
    missing = [n for n in expected_matrix()
               if n not in validated and n not in skipped]
    if missing:
        raise RuntimeError(
            "dry-run matrix scenarios silently skipped: " + ", ".join(missing))
    print(f"# matrix: {len(validated)} scenario(s) validated, "
          f"{len(skipped)} skipped with reasons, 0 silent")


def dry_run():
    run(dry=True)


if __name__ == "__main__":
    if _PARTITION_CHILD_FLAG in sys.argv:
        _partition_child()
    else:
        run()
