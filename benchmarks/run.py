"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived...`` CSV rows (benchmarks/common.emit).

  bench_breakdown       Fig. 1  execution-time breakdown
  bench_agg_vs_pgr      Fig. 2  Aggregation vs PageRank + reorder guideline
  bench_phase_metrics   Fig. 2(f,g)/Table 3  hybrid execution patterns
  bench_ordering        Table 4 phase-ordering impact (+distributed halo)
  bench_feature_length  Fig. 5  input/output length sweeps
  bench_kernels         beyond-paper: Pallas kernels + fused dataflow
  bench_plan            planner sweep: backend x ordering x fusion scenarios
  roofline              deliverable (g): dry-run roofline table

Usage: PYTHONPATH=src python -m benchmarks.run [--dry-run] [module ...]

``--dry-run`` routes through the execution planner only: every scenario
plan is built and validated (tiny graphs, no timing) -- the pre-merge
smoke check (scripts/smoke.sh).
"""

import sys
import traceback


def main() -> None:
    argv = sys.argv[1:]
    dry = "--dry-run" in argv
    argv = [a for a in argv if a != "--dry-run"]

    from benchmarks import (bench_agg_vs_pgr, bench_breakdown,
                            bench_feature_length, bench_kernels,
                            bench_ordering, bench_phase_metrics, bench_plan,
                            roofline)
    modules = {
        "bench_breakdown": bench_breakdown,
        "bench_agg_vs_pgr": bench_agg_vs_pgr,
        "bench_phase_metrics": bench_phase_metrics,
        "bench_ordering": bench_ordering,
        "bench_feature_length": bench_feature_length,
        "bench_kernels": bench_kernels,
        "bench_plan": bench_plan,
        "roofline": roofline,
    }
    if dry:
        # planner-path smoke: build+validate every scenario plan, no timing.
        # A selected module without a dry-run mode is a HARD failure -- a
        # scenario silently skipped here would merge unvalidated
        # (scripts/smoke.sh counts on this exit code).
        selected = argv or ["bench_plan"]
        failures = 0
        for name in selected:
            print(f"# === {name} (dry) ===")
            try:
                mod = modules[name]
                if hasattr(mod, "dry_run"):
                    mod.dry_run()
                else:
                    raise RuntimeError(
                        f"{name} has no dry_run(); its scenarios would be "
                        "silently skipped -- add one or drop it from the "
                        "dry-run selection")
            except Exception:  # noqa: BLE001
                failures += 1
                traceback.print_exc()
        if failures:
            raise SystemExit(f"{failures} dry-run module(s) failed")
        return

    selected = argv or list(modules)
    failures = 0
    for name in selected:
        print(f"# === {name} ===")
        try:
            modules[name].run()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == '__main__':
    main()
