# NOTE: no XLA_FLAGS here by design -- smoke tests and benches must see the
# single real CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (tests/test_distributed.py).
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
